"""Fleet-scale sweep: tick throughput of the vectorized array engine from
the paper's 2k GPUs up to 100k-instance campaigns (HEPCloud-scale), plus a
head-to-head against the seed per-instance object engine at 10k.

    PYTHONPATH=src python -m benchmarks.fleet_scale
    PYTHONPATH=src python -m benchmarks.fleet_scale --sizes 2000,10000 \
        --ticks 30 --compare-at 10000

Prints ``name,us_per_call,derived`` CSV rows (run.py idiom) where
``us_per_call`` is microseconds per simulated tick and ``derived`` is
instance-ticks/second.  The comparison row's derived value is the
array/object speedup — the acceptance bar is >= 20x at 10k instances.
"""
from __future__ import annotations

import argparse
import time

from repro.core.provider import heterogeneous_catalog
from repro.core.simulator import CloudSimulator, SimConfig


def _catalog_for(size: int):
    base = sum(p.total_capacity
               for p in heterogeneous_catalog().values())
    scale = max(1.0, 1.3 * size / base)
    return heterogeneous_catalog(capacity_scale=scale)


def _build(size: int, engine: str, seed: int = 2021) -> CloudSimulator:
    cfg = SimConfig(duration_h=1e9, seed=seed, engine=engine,
                    min_queue=max(4000, int(size * 1.5)))
    sim = CloudSimulator(_catalog_for(size), 1e12, cfg)
    sim.prov.scale_to(size, 0.0)
    return sim


def time_ticks(size: int, engine: str, ticks: int, warmup: int = 4):
    """Seconds per tick at a steady fleet of ``size`` instances."""
    sim = _build(size, engine)
    for _ in range(warmup):
        sim.step()
    t0 = time.perf_counter()
    for _ in range(ticks):
        sim.step()
    dt = (time.perf_counter() - t0) / ticks
    assert sim.prov.total_running() >= size * 0.95, \
        f"fleet fell below target: {sim.prov.total_running()}/{size}"
    return dt, sim


def sweep(sizes, ticks: int, compare_at: int, compare_ticks: int):
    rows = []
    print("name,us_per_call,derived")
    for size in sizes:
        per_tick, sim = time_ticks(size, "array", ticks)
        rate = size / per_tick
        print(f"fleet_tick_array_{size},{per_tick * 1e6:.1f},{rate:.3e}")
        print(f"    running={sim.prov.total_running()} "
              f"busy={sim.ce.stats()['pilots_busy']} "
              f"preemptions={sim.ce.preemption_events} "
              f"spent=${sim.ledger.spent:,.0f}")
        rows.append((size, per_tick))
    if compare_at:
        a_tick, _ = time_ticks(compare_at, "array", compare_ticks)
        o_tick, _ = time_ticks(compare_at, "object", compare_ticks)
        speedup = o_tick / a_tick
        print(f"fleet_tick_speedup_{compare_at},{a_tick * 1e6:.1f},"
              f"{speedup:.1f}")
        print(f"    object={o_tick * 1e3:.1f} ms/tick "
              f"array={a_tick * 1e3:.1f} ms/tick -> {speedup:.1f}x "
              f"(bar: >=20x)")
        return rows, speedup
    return rows, None


def bench_fleet_tick_throughput():
    """run.py-registered entry: modest sizes so the full bench suite stays
    quick; the standalone CLI does the 100k sweep."""
    per_tick_2k, _ = time_ticks(2000, "array", 20)
    a_tick, _ = time_ticks(10000, "array", 12)
    o_tick, _ = time_ticks(10000, "object", 12)
    rows = [f"    2k: {per_tick_2k * 1e3:.2f} ms/tick   "
            f"10k: array {a_tick * 1e3:.2f} vs object "
            f"{o_tick * 1e3:.1f} ms/tick"]
    return a_tick * 1e6, round(o_tick / a_tick, 1), rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="2000,10000,50000,100000")
    ap.add_argument("--ticks", type=int, default=24)
    ap.add_argument("--compare-at", type=int, default=10000,
                    help="fleet size for the array-vs-object head-to-head "
                         "(0 disables)")
    ap.add_argument("--compare-ticks", type=int, default=12)
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",") if s]
    sweep(sizes, args.ticks, args.compare_at, args.compare_ticks)


if __name__ == "__main__":
    main()
